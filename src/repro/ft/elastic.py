"""Elastic training runner: checkpoint/restart + mesh re-formation.

The runner executes a step loop; on failure (device loss simulated via
FailureInjector, or any exception from the step) it:
  1. drops to the last valid checkpoint,
  2. re-forms the mesh from the surviving device count (any divisor of the
     global batch is acceptable — data parallelism rescales),
  3. resumes, replaying the data stream deterministically from the restored
     step (the pipeline is seeded by step index, so no data is skipped or
     repeated).

On CPU the "devices" are XLA host devices; the policy logic (what to do on
failure) is the deployable artifact and is what the tests exercise.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from .checkpoint import CheckpointManager


class FailureInjector:
    """Deterministic failure schedule: {step: n_devices_lost_or_exception}."""

    def __init__(self, fail_at: dict[int, str] | None = None):
        self.fail_at = dict(fail_at or {})
        self.fired: list[int] = []

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.append(step)
            raise RuntimeError(f"injected failure at step {step}: "
                               f"{self.fail_at[step]}")


@dataclasses.dataclass
class ReplicaDrill:
    """Kill-and-restore drill for a SERVING replica (not a training loop).

    Drives ``serve_fn(step)`` through ``total_steps`` probe steps against a
    live replica; at each step the injector may kill the replica
    (RuntimeError), after which ``restore_fn()`` must stand up a fresh one
    from its last checkpoint and the SAME step is replayed against it.
    `run` returns the per-step results plus which steps saw a kill — the
    registry tests replay identical queries through the drill and assert
    the killed-and-restored replica's answers are bit-identical to the
    uninterrupted ones.
    """

    serve_fn: Callable[[int], object]   # step -> result (raises when killed)
    restore_fn: Callable[[], None]      # stand the replica back up
    total_steps: int
    max_restarts: int = 10

    def run(self, injector: FailureInjector | None = None):
        results: list[object] = []
        killed_at: list[int] = []
        restarts = 0
        step = 0
        while step < self.total_steps:
            try:
                if injector is not None:
                    injector.maybe_fail(step)
                results.append(self.serve_fn(step))
                step += 1
            except RuntimeError:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                killed_at.append(step)
                self.restore_fn()
                # the killed step replays against the restored replica
        return results, killed_at


@dataclasses.dataclass
class ElasticRunner:
    make_state: Callable[[], object]          # fresh (params, opt, ...) state
    step_fn: Callable[[object, int], object]  # (state, step) -> state
    ckpt: CheckpointManager
    total_steps: int
    checkpoint_every: int = 10
    max_restarts: int = 10
    on_restart: Callable[[int], None] | None = None

    def run(self, injector: FailureInjector | None = None):
        restarts = 0
        state = self.make_state()
        restored, step0, _ = self.ckpt.restore(state)
        state = restored if restored is not None else state
        step = (step0 + 1) if step0 is not None else 0
        while step < self.total_steps:
            try:
                if injector is not None:
                    injector.maybe_fail(step)
                state = self.step_fn(state, step)
                if (step + 1) % self.checkpoint_every == 0 or \
                        step + 1 == self.total_steps:
                    self.ckpt.save(step, state)
                step += 1
            except RuntimeError:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                if self.on_restart is not None:
                    self.on_restart(restarts)
                # re-form: fresh state structure, restore last good checkpoint
                state = self.make_state()
                restored, step0, _ = self.ckpt.restore(state)
                state = restored if restored is not None else state
                step = (step0 + 1) if step0 is not None else 0
        self.ckpt.wait()
        return state, restarts
