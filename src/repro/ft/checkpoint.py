"""Sharded checkpointing with atomic commit, checksums and async writes.

Layout (per step):
    <dir>/step_000123.tmp/          -- written first
        shard_00000.npz             -- flat {index -> array} leaves
        manifest.json               -- treedef, shapes, dtypes, crc32 per shard
    <dir>/step_000123/              -- atomic rename on success

Restore validates checksums and the pytree structure; partial/corrupt
checkpoints are skipped (the manager falls back to the previous step), which
is what a restarted pod must do after a mid-write failure.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, str(treedef)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, extra: dict | None = None, block: bool = False):
        """Snapshot to host memory synchronously, write (a)synchronously."""
        leaves, treedef = _flatten(tree)
        host = [np.asarray(l) for l in leaves]
        self.wait()
        if self.async_write and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, treedef, extra or {}),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host, treedef, extra or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: list, treedef: str, extra: dict):
        name = f"step_{step:09d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        shard_file = os.path.join(tmp, "shard_00000.npz")
        np.savez(shard_file, **{str(i): a for i, a in enumerate(host)})
        with open(shard_file, "rb") as f:
            crc = zlib.crc32(f.read())
        manifest = {
            "step": step, "treedef": treedef, "n_leaves": len(host),
            "shards": {"shard_00000.npz": crc},
            "shapes": [list(a.shape) for a in host],
            "dtypes": [str(a.dtype) for a in host],
            "extra": extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, final) if not os.path.exists(final) else shutil.rmtree(tmp)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for n in os.listdir(self.dir):
            if n.startswith("step_") and not n.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, n, "manifest.json")):
                out.append(int(n[5:]))
        return sorted(out)

    def _validate(self, path: str) -> dict | None:
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
            for shard, crc in manifest["shards"].items():
                with open(os.path.join(path, shard), "rb") as f:
                    if zlib.crc32(f.read()) != crc:
                        return None
            return manifest
        except (OSError, json.JSONDecodeError, KeyError):
            return None

    def restore(self, tree_like, step: int | None = None):
        """Restore into the structure of ``tree_like``.

        Returns (tree, step, extra) or (None, None, None) if no valid
        checkpoint exists.  Corrupt checkpoints are skipped, newest-first.
        """
        self.wait()
        steps = self.all_steps()
        if step is not None:
            steps = [s for s in steps if s == step]
        for s in reversed(steps):
            path = os.path.join(self.dir, f"step_{s:09d}")
            manifest = self._validate(path)
            if manifest is None:
                continue
            leaves, treedef = _flatten(tree_like)
            if manifest["n_leaves"] != len(leaves) or manifest["treedef"] != str(treedef):
                continue
            data = np.load(os.path.join(path, "shard_00000.npz"))
            import jax.numpy as jnp
            new_leaves = [jnp.asarray(data[str(i)]) for i in range(len(leaves))]
            ok = all(list(a.shape) == list(l.shape)
                     for a, l in zip(new_leaves, jax.tree.leaves(tree_like)))
            if not ok:
                continue
            restored = jax.tree.unflatten(jax.tree.structure(tree_like), new_leaves)
            return restored, s, manifest.get("extra", {})
        return None, None, None

    def restore_flat(self, step: int | None = None):
        """Structure-free restore: the flat leaf list exactly as saved.

        `restore` needs a ``tree_like`` with the checkpoint's structure and
        shapes known up front, which a variable-shape state (e.g. a
        streaming index whose part count changes across snapshots) cannot
        provide.  This variant trusts the manifest instead: checksums and
        per-leaf shapes are still validated, corrupt checkpoints are still
        skipped newest-first, but the caller receives plain numpy leaves
        (``(leaves, step, extra)``; ``(None, None, None)`` when nothing
        valid exists) and rebuilds its own structure — e.g.
        `core.streaming.StreamingSNNIndex.from_state`.
        """
        self.wait()
        steps = self.all_steps()
        if step is not None:
            steps = [s for s in steps if s == step]
        for s in reversed(steps):
            path = os.path.join(self.dir, f"step_{s:09d}")
            manifest = self._validate(path)
            if manifest is None:
                continue
            try:
                data = np.load(os.path.join(path, "shard_00000.npz"))
                leaves = [np.asarray(data[str(i)])
                          for i in range(manifest["n_leaves"])]
            except (OSError, KeyError, ValueError):
                continue
            if [list(a.shape) for a in leaves] != manifest["shapes"]:
                continue
            return leaves, s, manifest.get("extra", {})
        return None, None, None
