"""Paper §6.4: DBSCAN with SNN region queries vs brute-force/kd-tree backends
— identical clusterings, SNN fastest (the paper's headline application).

Run:  PYTHONPATH=src python examples/dbscan_clustering.py
"""
import time


from repro.core.dbscan import dbscan, normalized_mutual_information as nmi
from repro.data.pipeline import make_blobs


def main():
    x, y = make_blobs(800, [(0, 0), (6, 0), (0, 6), (6, 6), (3, 3)],
                      std=0.5, seed=0)
    print(f"clustering {x.shape[0]} points in {x.shape[1]}D, 5 true blobs")

    results = {}
    for backend in ("snn", "snn-graph", "brute", "kdtree"):
        t0 = time.perf_counter()
        labels = dbscan(x, eps=0.7, min_samples=5, backend=backend)
        dt = time.perf_counter() - t0
        results[backend] = labels
        print(f"{backend:7s}: {dt*1e3:8.1f} ms, "
              f"{labels.max()+1} clusters, NMI={nmi(labels, y):.4f}")

    assert (results["snn"] == results["snn-graph"]).all()
    assert (results["snn"] == results["brute"]).all()
    assert (results["snn"] == results["kdtree"]).all()
    print("all backends return identical clusterings (exactness)")


if __name__ == "__main__":
    main()
