"""SNN as graph substrate: build a radius graph over a point cloud with SNN
(exact, fast), then train the assigned GAT architecture on it.

Run:  PYTHONPATH=src python examples/radius_graph_gnn.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_index, query_radius_batch
from repro.data.pipeline import make_blobs
from repro.models import gnn
from repro.optim import adamw
from repro.optim.optimizers import apply_updates


def radius_graph(x: np.ndarray, r: float):
    """Edge list (src, dst) of all pairs within r, via one SNN batch query."""
    index = build_index(x)
    res = query_radius_batch(index, x, r, return_distance=False)
    src = np.concatenate([np.full(len(nb), i) for i, nb in enumerate(res)])
    dst = np.concatenate(res)
    return src.astype(np.int32), dst.astype(np.int32)


def main():
    x, y = make_blobs(150, [(0, 0), (4, 0), (0, 4), (4, 4)], std=0.6, seed=0)
    t0 = time.perf_counter()
    src, dst = radius_graph(x, r=1.0)
    print(f"radius graph: {x.shape[0]} nodes, {src.size} edges "
          f"({time.perf_counter()-t0:.3f}s via SNN)")

    cfg = gnn.GATConfig(name="radius-gat", d_in=2, d_hidden=8, n_heads=4,
                        n_classes=4)
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"x": jnp.asarray(x), "src": jnp.asarray(src),
             "dst": jnp.asarray(dst), "labels": jnp.asarray(y),
             "mask": jnp.asarray(np.arange(x.shape[0]) % 2 == 0)}  # half train
    opt = adamw(lr=5e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        loss, g = jax.value_and_grad(
            lambda p: gnn.loss_full(p, batch, cfg))(params)
        upd, state = opt.update(g, state, params)
        return apply_updates(params, upd), state, loss

    for i in range(120):
        params, state, loss = step(params, state)
        if i % 30 == 0:
            print(f"step {i:3d} loss {float(loss):.4f}")

    logits = gnn.forward_full(params, batch["x"], batch["src"], batch["dst"], cfg)
    test = ~np.asarray(batch["mask"])
    acc = (np.asarray(logits).argmax(1)[test] == y[test]).mean()
    print(f"held-out accuracy: {acc:.3f}")
    assert acc > 0.9


if __name__ == "__main__":
    main()
