"""Quickstart: index a dataset, run exact radius queries, compare with brute
force, and use every supported metric.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (BruteForce2, build_index, query_counts, query_radius,
                        query_radius_batch)
from repro.data.pipeline import make_uniform


def main():
    # ---- index ----
    x = make_uniform(50_000, 16, seed=0)
    index = build_index(x)                       # Algorithm 1: O(n log n)
    print(f"indexed {index.n} points, d={index.d}")

    # ---- single query (Algorithm 2) ----
    q = x[123] + 0.01
    idx, dist = query_radius(index, q, radius=0.4)
    print(f"single query: {len(idx)} neighbors, nearest at {dist.min():.4f}")

    # ---- batched queries (level-3 BLAS grouping) ----
    qs = make_uniform(256, 16, seed=1)
    results = query_radius_batch(index, qs, radius=0.4)
    sizes = [len(i) for i, _ in results]
    print(f"batch of 256: mean return {np.mean(sizes):.1f} points")

    # ---- two-pass CSR engine (device path; exact, variable-length) ----
    from repro.core import query_radius_csr
    near = x[:256] + 0.01                        # queries near the data
    want = query_radius_batch(index, near, radius=0.4, return_distance=False)
    csr = query_radius_csr(index, near, radius=0.4)
    assert csr.nnz == sum(len(w) for w in want) and csr.nnz > 0
    print(f"csr engine: {csr.nnz} total neighbors across {csr.m} queries, "
          f"largest row {int(np.diff(csr.indptr).max())}")

    # ---- exactness check vs brute force ----
    bf = BruteForce2(x)
    want = bf.query_radius(qs[:8], 0.4)
    got = query_radius_batch(index, qs[:8], 0.4, return_distance=False)
    assert all(set(a.tolist()) == set(b.tolist()) for a, b in zip(got, want))
    print("exactness vs brute force: OK")

    # ---- streaming appends (LSM deltas on frozen mu/v1; exact) ----
    from repro.core import StreamingSNNIndex
    stream = StreamingSNNIndex(x)
    stream.append(make_uniform(2_000, 16, seed=3))     # O(b log b), no re-index
    scsr = stream.query_radius_csr(qs[:16], 0.4, return_distance=False)
    fresh = build_index(stream.raw)
    swant = query_radius_batch(fresh, qs[:16], 0.4, return_distance=False)
    assert all(sorted(scsr.row(i).tolist()) == sorted(w.tolist())
               for i, w in enumerate(swant))
    print(f"streaming: {stream.n} points in {len(stream.parts)} segments, "
          f"appends exact vs fresh index: OK")

    # ---- other metrics ----
    for metric, radius in [("cosine", 0.25), ("angular", 0.7), ("mips", 4.2)]:
        im = build_index(x, metric=metric)
        c = query_counts(im, qs[:32], radius)
        print(f"{metric:8s} radius={radius}: mean neighbors {c.mean():.1f}")


if __name__ == "__main__":
    main()
