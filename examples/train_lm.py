"""End-to-end training driver: a ~100M-parameter GQA transformer trained for a
few hundred steps on the synthetic Markov stream, with checkpointing and an
injected mid-run failure + elastic resume (the full fault-tolerance path).

Run:  PYTHONPATH=src python examples/train_lm.py  [--steps 300]
"""
import argparse
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import LMSyntheticDataset
from repro.ft.checkpoint import CheckpointManager
from repro.ft.elastic import ElasticRunner, FailureInjector
from repro.models.transformer import (TransformerConfig, init_params, loss_fn,
                                      param_count)
from repro.optim import adamw, clip_by_global_norm, warmup_cosine
from repro.optim.optimizers import apply_updates


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    args = ap.parse_args()

    # ~100M params: 8L x 512d + 32k vocab
    cfg = TransformerConfig(
        name="lm100m", n_layers=args.layers, d_model=args.d_model,
        n_heads=8, n_kv_heads=4, head_dim=args.d_model // 8,
        d_ff=4 * args.d_model, vocab=32_000, max_seq=256, remat=False)
    ds = LMSyntheticDataset(vocab=cfg.vocab, seq_len=128, batch=8)
    opt = adamw(lr=warmup_cosine(3e-4, 20, args.steps), weight_decay=0.01)

    def make_state():
        params = init_params(jax.random.PRNGKey(0), cfg)
        return {"params": params, "opt": opt.init(params)}

    n_params = param_count(make_state()["params"])
    print(f"model: {n_params/1e6:.1f}M params")

    @jax.jit
    def train_step(state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg))(state["params"])
        grads, _ = clip_by_global_norm(grads, 1.0)
        upd, new_opt = opt.update(grads, state["opt"], state["params"])
        return {"params": apply_updates(state["params"], upd),
                "opt": new_opt}, loss

    losses = []

    def step_fn(state, i):
        batch = jax.tree.map(jnp.asarray, ds.batch_at(i))
        state, loss = train_step(state, batch)
        losses.append(float(loss))
        if i % 25 == 0:
            print(f"step {i:4d}  loss {float(loss):.4f}")
        return state

    ckdir = tempfile.mkdtemp(prefix="lm100m_ck_")
    try:
        runner = ElasticRunner(
            make_state, step_fn, CheckpointManager(ckdir, async_write=False),
            total_steps=args.steps, checkpoint_every=50,
            on_restart=lambda r: print(f"  !! elastic restart #{r}"))
        injector = FailureInjector({args.steps // 2: "simulated node loss"})
        _, restarts = runner.run(injector)
        print(f"finished with {restarts} elastic restart(s)")
        first, last = np.mean(losses[:10]), np.mean(losses[-10:])
        print(f"loss {first:.3f} -> {last:.3f} "
              f"({'LEARNING OK' if last < first - 0.5 else 'no progress?'})")
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)


if __name__ == "__main__":
    main()
