"""End-to-end serving driver (the paper's kind of system is retrieval, so the
end-to-end example is a served index under batched request load):

* stands up ONE server fronting an `IndexRegistry` with TWO tenants —
  a 40k-point corpus and a separate 25k-point corpus in a different
  dimensionality — sharing the dispatcher thread and device-memory budget,
* drives 1,000 requests with deadline-aware continuous batching (each
  request carries an SLO budget; light load flushes immediately, heavy
  load fuses arrivals until the oldest request's remaining budget runs
  out) — mixed per-request radii plus a slice of exact-kNN traffic, all
  fused per (tenant, batch) into one engine dispatch,
* streams 2k new points into tenant A mid-run (an O(b log b) LSM delta
  append — no re-index, no serving gap), then FORCES a full re-index of
  tenant B mid-run: with `serve_warm_plans` (default) the next
  generation's plan is built and warmed on the rebuild caller's thread and
  swapped atomically, so in-flight traffic never pays the rebuild,
* reports per-tenant throughput + queue-delay/service split and validates
  results against brute force.

Run:  PYTHONPATH=src python examples/serve_snn.py
"""
import threading
import time

import numpy as np

from repro.configs.snn_default import SNNConfig
from repro.core import BruteForce2
from repro.data.pipeline import make_uniform
from repro.serving.server import Request, SNNServer


def main():
    n_a, d_a, n_b, d_b, n_req = 40_000, 16, 25_000, 8, 1_000
    cfg = SNNConfig(serve_batch=128, serve_slo_ms=50.0, max_neighbors=2048)
    t0 = time.perf_counter()
    server = SNNServer(make_uniform(n_a, d_a, seed=0), cfg)  # tenant "default"
    server.registry.create("logs", make_uniform(n_b, d_b, seed=3), cfg)
    print(f"index build: {time.perf_counter()-t0:.3f}s "
          f"for {n_a}x{d_a} (default) + {n_b}x{d_b} (logs)")
    server.start()

    rng = np.random.default_rng(1)
    queries = rng.random((n_req, d_a)).astype(np.float32)
    log_queries = rng.random((n_req, d_b)).astype(np.float32)
    # every request its own radius: the dispatcher fuses a whole batch into
    # ONE packed engine execution per tenant regardless of how many radii
    radii = rng.uniform(0.85, 0.95, n_req)
    knn_every = 20   # ... plus a 5% slice of exact-kNN traffic
    logs_every = 4   # every 4th request hits the second tenant

    t0 = time.perf_counter()
    for i in range(n_req):
        if i % logs_every == 0:
            server.submit(Request(query=log_queries[i], radius=0.9, id=i,
                                  tenant="logs"))
        elif i % knn_every == 0:
            server.submit(Request(query=queries[i], k=10, id=i))
        else:
            server.submit(Request(query=queries[i], radius=float(radii[i]),
                                  id=i))
        if i == n_req // 3:
            # mid-stream online update: a sorted delta segment on the frozen
            # base mu/v1 — no power iteration, no full re-sort
            t1 = time.perf_counter()
            server.append(make_uniform(2_000, d_a, seed=7))
            print(f"  online append (+2k points, default): "
                  f"{time.perf_counter()-t1:.3f}s")
        if i == 2 * n_req // 3:
            # mid-stream FULL re-index of the other tenant, off-thread: the
            # new generation's plan is built + warmed before the atomic
            # swap, so the traffic above keeps its steady-state latency
            gen = server.runtime("logs").index.generation
            rebuild_th = threading.Thread(
                target=server.rebuild, kwargs={"tenant": "logs"})
            rebuild_th.start()
            print(f"  full rebuild of 'logs' launched mid-run "
                  f"(generation {gen} -> warm-swapped)")
    resps = [server.result(i) for i in range(n_req)]
    wall = time.perf_counter() - t0
    rebuild_th.join()
    server.stop()
    print(f"  'logs' now at generation "
          f"{server.runtime('logs').index.generation}")

    for tenant in ("default", "logs"):
        sub = [r for r in resps
               if (tenant == "logs") == (r.id % logs_every == 0)]
        lat = np.asarray([r.latency_ms for r in sub])
        qd = np.asarray([r.queue_delay_ms for r in sub])
        print(f"{tenant}: {len(sub)} requests, latency "
              f"p50={np.percentile(lat, 50):.1f}ms "
              f"p99={np.percentile(lat, 99):.1f}ms "
              f"(queue p50={np.percentile(qd, 50):.2f}ms)")
    print(f"{n_req} queries in {wall:.2f}s -> {n_req/wall:.0f} qps "
          f"across both tenants")

    # exactness spot check on the final index states (base + delta for the
    # default tenant, post-rebuild generation for logs) vs brute force
    check = server.query_batch(queries[:16], radii[:16])
    bf = BruteForce2(server.data)
    want = bf.query_radius(queries[:16], radii[:16])
    assert all(set(idx.tolist()) == set(w.tolist())
               for (idx, _), w in zip(check, want))
    bf_logs = BruteForce2(server.runtime("logs").index.raw)
    check = server.query_batch(log_queries[:16], 0.9, tenant="logs")
    want = bf_logs.query_radius(log_queries[:16],
                                np.full(16, 0.9, np.float64))
    assert all(set(idx.tolist()) == set(w.tolist())
               for (idx, _), w in zip(check, want))
    print("served results exact vs brute force (both tenants): OK")


if __name__ == "__main__":
    main()
