"""End-to-end serving driver (the paper's kind of system is retrieval, so the
end-to-end example is a served index under batched request load):

* builds an SNN index over a 100k-point corpus,
* stands up the dynamic-batching server,
* drives 2,000 radius queries through it while streaming 5k new points in
  (an O(b log b) LSM delta append on the live index — no re-index, no
  serving gap: the paper's "flexibility" claim made sublinear),
* reports throughput/latency and validates results against brute force.

Run:  PYTHONPATH=src python examples/serve_snn.py
"""
import time

import numpy as np

from repro.configs.snn_default import SNNConfig
from repro.core import BruteForce2
from repro.data.pipeline import make_uniform
from repro.serving.server import Request, SNNServer


def main():
    n, d, n_req = 100_000, 32, 2_000
    data = make_uniform(n, d, seed=0)
    t0 = time.perf_counter()
    server = SNNServer(data, SNNConfig(serve_batch=128, serve_timeout_ms=2.0,
                                       max_neighbors=2048))
    print(f"index build: {time.perf_counter()-t0:.3f}s for {n}x{d}")
    server.start()

    rng = np.random.default_rng(1)
    queries = rng.random((n_req, d)).astype(np.float32)
    radius = 0.9

    t0 = time.perf_counter()
    for i in range(n_req):
        server.submit(Request(query=queries[i], radius=radius, id=i))
        if i == n_req // 2:
            # mid-stream online update: a sorted delta segment on the frozen
            # base mu/v1 — no power iteration, no full re-sort
            t1 = time.perf_counter()
            server.append(make_uniform(5_000, d, seed=7))
            print(f"  online append (+5k points): "
                  f"{time.perf_counter()-t1:.3f}s")
    lat = []
    for i in range(n_req):
        lat.append(server.result(i).latency_ms)
    wall = time.perf_counter() - t0
    server.stop()

    lat = np.asarray(lat)
    print(f"{n_req} queries in {wall:.2f}s -> {n_req/wall:.0f} qps")
    print(f"latency p50={np.percentile(lat, 50):.1f}ms "
          f"p99={np.percentile(lat, 99):.1f}ms")

    # exactness spot check on the final index state (base + delta segments)
    check = server.query_batch(queries[:16], radius)
    bf = BruteForce2(server.data)
    want = bf.query_radius(queries[:16], radius)
    assert all(set(idx.tolist()) == set(w.tolist())
               for (idx, _), w in zip(check, want))
    print("served results exact vs brute force: OK")


if __name__ == "__main__":
    main()
