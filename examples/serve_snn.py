"""End-to-end serving driver (the paper's kind of system is retrieval, so the
end-to-end example is a served index under batched request load):

* builds an SNN index over a 100k-point corpus,
* stands up the dynamic-batching server,
* drives 2,000 requests — mixed per-request radii plus a slice of exact-kNN
  traffic, all fused per batch into one engine dispatch — while streaming
  5k new points in
  (an O(b log b) LSM delta append on the live index — no re-index, no
  serving gap: the paper's "flexibility" claim made sublinear),
* reports throughput/latency and validates results against brute force.

Run:  PYTHONPATH=src python examples/serve_snn.py
"""
import time

import numpy as np

from repro.configs.snn_default import SNNConfig
from repro.core import BruteForce2
from repro.data.pipeline import make_uniform
from repro.serving.server import Request, SNNServer


def main():
    n, d, n_req = 100_000, 32, 2_000
    data = make_uniform(n, d, seed=0)
    t0 = time.perf_counter()
    server = SNNServer(data, SNNConfig(serve_batch=128, serve_timeout_ms=2.0,
                                       max_neighbors=2048))
    print(f"index build: {time.perf_counter()-t0:.3f}s for {n}x{d}")
    server.start()

    rng = np.random.default_rng(1)
    queries = rng.random((n_req, d)).astype(np.float32)
    # every request its own radius: the dispatcher fuses a whole batch into
    # ONE packed engine execution regardless of how many radii it spans
    radii = rng.uniform(0.85, 0.95, n_req)
    # ... and a 5% slice of exact-kNN traffic through the same dispatcher
    knn_every = 20

    t0 = time.perf_counter()
    for i in range(n_req):
        if i % knn_every == 0:
            server.submit(Request(query=queries[i], k=10, id=i))
        else:
            server.submit(Request(query=queries[i], radius=float(radii[i]),
                                  id=i))
        if i == n_req // 2:
            # mid-stream online update: a sorted delta segment on the frozen
            # base mu/v1 — no power iteration, no full re-sort
            t1 = time.perf_counter()
            server.append(make_uniform(5_000, d, seed=7))
            print(f"  online append (+5k points): "
                  f"{time.perf_counter()-t1:.3f}s")
    lat = []
    for i in range(n_req):
        lat.append(server.result(i).latency_ms)
    wall = time.perf_counter() - t0
    server.stop()

    lat = np.asarray(lat)
    print(f"{n_req} queries in {wall:.2f}s -> {n_req/wall:.0f} qps")
    print(f"latency p50={np.percentile(lat, 50):.1f}ms "
          f"p99={np.percentile(lat, 99):.1f}ms")

    # exactness spot check on the final index state (base + delta segments):
    # per-query radius vector straight through the host path and brute force
    check = server.query_batch(queries[:16], radii[:16])
    bf = BruteForce2(server.data)
    want = bf.query_radius(queries[:16], radii[:16])
    assert all(set(idx.tolist()) == set(w.tolist())
               for (idx, _), w in zip(check, want))
    ids, _ = server.index.query_knn(queries[:1], 10)
    assert set(ids[0].tolist()) <= set(
        bf.query_radius(queries[:1], 10.0)[0].tolist())
    print("served results exact vs brute force: OK")


if __name__ == "__main__":
    main()
