"""SNN-MIPS candidate retrieval for the recsys stack (assigned archs mind /
bert4rec): score one user against 1M candidates via (a) full GEMM and (b) the
paper's MIPS lift + sorted-window pruning — identical top results, with the
pruned candidate fraction reported.

The SNN side is ONE bichromatic join (`core.join` via
`models.recsys.retrieve_above`): all K interest capsules stream through the
lifted candidate index in a single call instead of K separate scans.

Run:  PYTHONPATH=src python examples/recsys_retrieval.py
"""
import time

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.core import build_index
from repro.models import recsys as rs


def main():
    cfg = get_arch("mind").make_config("retrieval_cand", reduced=True)
    params = rs.mind_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    hist = rng.integers(0, cfg.n_items, (1, cfg.hist_len)).astype(np.int32)

    # user representation: K interest capsules
    interests = np.asarray(rs.mind_user_tower(params, hist, cfg))[0]  # (K, D)
    items = np.asarray(params["items"])                               # (C, D)
    c = items.shape[0]

    # (a) exhaustive scoring
    t0 = time.perf_counter()
    scores = (interests @ items.T).max(axis=0)
    top_full = np.argsort(-scores)[:10]
    t_full = time.perf_counter() - t0

    # (b) SNN MIPS: lift the corpus once, join ALL interest capsules at once
    t0 = time.perf_counter()
    index = build_index(items, metric="mips")
    t_index = time.perf_counter() - t0
    # retrieve everything >= the top-10 score.  The cutoff is placed halfway
    # between the 10th and 11th scores: a threshold EXACTLY at the 10th
    # score would make that item's membership rounding-dependent (the GEMM
    # and the engine compute the same score along different float32 chains —
    # docs/architecture.md's float-boundary caveat), while the midpoint
    # gives both sides a margin of half the score gap
    srt = np.sort(scores)
    thresh = float(srt[-10] + srt[-11]) / 2.0
    t0 = time.perf_counter()
    csr = rs.retrieve_above(interests, items, thresh, index=index)
    t_snn = time.perf_counter() - t0
    cand = set(csr.indices.tolist())       # union over the K capsule rows
    top_snn = sorted(cand, key=lambda i: -scores[i])[:10]

    assert set(top_full.tolist()) == set(top_snn), "SNN-MIPS must be exact"
    print(f"candidates: {c}; top-10 identical: OK")
    print(f"full GEMM scoring: {t_full*1e3:.2f} ms")
    print(f"SNN index: {t_index*1e3:.2f} ms (amortized over queries)")
    print(f"SNN pruned scoring: {t_snn*1e3:.2f} ms, "
          f"scanned {len(cand)}/{c} candidates "
          f"({100*len(cand)/c:.2f}% of corpus)")


if __name__ == "__main__":
    main()
