"""SNN-MIPS candidate retrieval for the recsys stack (assigned archs mind /
bert4rec): score one user against 1M candidates via (a) full GEMM and (b) the
paper's MIPS lift + sorted-window pruning — identical top results, with the
pruned candidate fraction reported.

Run:  PYTHONPATH=src python examples/recsys_retrieval.py
"""
import time

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.core import build_index, query_radius
from repro.models import recsys as rs


def main():
    cfg = get_arch("mind").make_config("retrieval_cand", reduced=True)
    params = rs.mind_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    hist = rng.integers(0, cfg.n_items, (1, cfg.hist_len)).astype(np.int32)

    # user representation: K interest capsules
    interests = np.asarray(rs.mind_user_tower(params, hist, cfg))[0]  # (K, D)
    items = np.asarray(params["items"])                               # (C, D)
    c = items.shape[0]

    # (a) exhaustive scoring
    t0 = time.perf_counter()
    scores = (interests @ items.T).max(axis=0)
    top_full = np.argsort(-scores)[:10]
    t_full = time.perf_counter() - t0

    # (b) SNN MIPS: one index reused for every interest capsule
    t0 = time.perf_counter()
    index = build_index(items, metric="mips")
    t_index = time.perf_counter() - t0
    thresh = np.sort(scores)[-10]          # retrieve everything >= top-10 score
    t0 = time.perf_counter()
    cand = set()
    for k in range(interests.shape[0]):
        idx, ip = query_radius(index, interests[k], thresh)
        cand.update(idx.tolist())
    t_snn = time.perf_counter() - t0
    top_snn = sorted(cand, key=lambda i: -scores[i])[:10]

    assert set(top_full.tolist()) == set(top_snn), "SNN-MIPS must be exact"
    print(f"candidates: {c}; top-10 identical: OK")
    print(f"full GEMM scoring: {t_full*1e3:.2f} ms")
    print(f"SNN index: {t_index*1e3:.2f} ms (amortized over queries)")
    print(f"SNN pruned scoring: {t_snn*1e3:.2f} ms, "
          f"scanned {len(cand)}/{c} candidates "
          f"({100*len(cand)/c:.2f}% of corpus)")


if __name__ == "__main__":
    main()
